"""Closed-loop remapping benchmark — the `repro.monitor` loop end to end.

One long-running workload, three episodes:

  * **steady state** — jittered (±1%) traffic windows.  The drift
    detector's hysteresis must hold: zero remaps.
  * **traffic shift** — a vertex subset's traffic scales by
    ``SHIFT_FACTOR``; the monitor must detect, pass the what-if gate,
    and commit an *incremental* remap (dirty-region pairs only, warm
    engine start).  The incremental remap is then timed against a
    from-scratch ``plan.execute`` on the same live graph: acceptance is
    >= 80% of the scratch remap's objective recovery at < 0.5x its
    wall-time — and **zero** engine retraces (the warm path reuses the
    compiled executable; this is a hard failure, not a metric).
  * **host eviction** — a ``StragglerMonitor`` flags a slow host,
    ``REBALANCE`` flows through ``attach`` into the same gate while the
    traffic shifts again, and the forced remap recovers the objective.

Writes ``BENCH_remap.json`` with per-window decision rows, the
predicted-vs-actual improvement of every committed remap, and the
headline recovery/latency/zero-trace acceptance block.

    python -m benchmarks.bench_remap [--smoke] [--out BENCH_remap.json]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import Mapper, MappingSpec
from repro.core.graph import from_edges, grid3d
from repro.monitor import MonitorConfig, RemapMonitor
from repro.runtime.fault_tolerance import StragglerMonitor
from repro.topology import make_topology

QUIET_WINDOWS = 4
SHIFT_FACTOR = 8.0
JITTER = 0.01
N_HOSTS = 4


def _workload(smoke: bool):
    # the shifted fraction shrinks with scale: drift episodes are local
    # (a tenant, a shard group), and the incremental path's value is
    # exactly that locality — at n=256 a quarter-graph shift plus its
    # 1-hop halo would dirty ~99% of vertices and degenerate into a
    # full remap
    if smoke:
        return grid3d(4, 4, 4), make_topology("torus", dims=[8, 8]), 0.25
    return grid3d(8, 8, 4), make_topology("torus", dims=[16, 16]), 0.125


def _jitter(g, rng):
    u, v, w = g.edge_list()
    return from_edges(g.n, u, v,
                      w * rng.uniform(1 - JITTER, 1 + JITTER, size=len(w)))


def _shift(g, vertices):
    """One tenant's internal traffic surges by ``SHIFT_FACTOR``."""
    u, v, w = g.edge_list()
    m = np.zeros(g.n, bool)
    m[vertices] = True
    return from_edges(g.n, u, v,
                      np.where(m[u] & m[v], w * SHIFT_FACTOR, w))


def _row(r):
    return {
        "window": r.window, "score": r.drift.score, "l1": r.drift.l1,
        "objective_delta": r.drift.objective_delta,
        "triggered": r.triggered, "remapped": r.remapped,
        "dirty": r.dirty, "active_pairs": r.active_pairs,
        "retraces": r.retraces, "forced_by": r.forced_by,
        "skipped": r.skipped,
        "predicted_improvement": (r.verdict.predicted_improvement
                                  if r.verdict else None),
    }


def _median_time(fn, repeats):
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run(report, smoke: bool = False, out: str = "BENCH_remap.json"):
    g, topo, shift_frac = _workload(smoke)
    spec = MappingSpec(construction="hierarchytopdown",
                       neighborhood="communication", neighborhood_dist=10,
                       engine="device", seed=0)
    plan = Mapper(topo, spec).lower_for(g, schedule="pow2")
    # alpha=0.7: by the time patience is met the EMA has converged to
    # ~0.9x of the shifted traffic, so the committed remap optimizes
    # (nearly) the true post-shift graph
    cfg = MonitorConfig(min_weight=0.01, alpha=0.7)
    mon = RemapMonitor(plan, g, config=cfg, seed=0)
    incumbent0 = mon.incumbent.copy()
    engines = plan.engines or []
    rng = np.random.default_rng(0)
    repeats = 1 if smoke else 3

    # -------------------------------------------------- episode 1: steady
    for _ in range(QUIET_WINDOWS):
        mon.observe_graph(_jitter(g, rng))
        mon.tick()
    quiet_remaps = mon.remaps

    # --------------------------------------------- episode 2: traffic shift
    warm_traces0 = sum(e.trace_count() for e in engines)
    # a contiguous grid block — one "tenant" whose internal traffic
    # surges — so the shift is local and internally connected
    shift_verts = np.arange(g.n // 8, g.n // 8 + int(shift_frac * g.n))
    true_shift = _shift(g, shift_verts)
    shift_reports = []
    for _ in range(5):
        mon.observe_graph(true_shift)
        shift_reports.append(mon.tick())
    commits = [r for r in shift_reports if r.remapped]
    committed = bool(commits)
    # the loop's cost for this episode: every warm remap it ran
    t_incr = sum(r.remap_seconds for r in shift_reports if r.triggered
                 and not r.skipped)

    # everyone is judged on the ground-truth shifted traffic, not the
    # EMA blend the monitor happened to commit on
    j_old = plan.objective(true_shift, incumbent0)
    j_incr = plan.objective(true_shift, mon.incumbent)
    scratch = plan.execute(true_shift, seed=0)
    j_scratch = scratch.final_objective
    t_scratch = _median_time(lambda: plan.execute(true_shift, seed=0),
                             repeats)

    gap_scratch = max(j_old - j_scratch, 1e-12)
    recovery = (j_old - j_incr) / gap_scratch
    time_ratio = t_incr / max(t_scratch, 1e-12)
    predicted = (commits[0].verdict.predicted_improvement
                 if committed else 0.0)
    actual = 1.0 - j_incr / max(j_old, 1e-12)

    # ------------------------------------------- episode 3: host eviction
    sm = StragglerMonitor(n_hosts=N_HOSTS, patience=2)
    mon.attach(sm)
    for _ in range(3):
        sm.record_step({h: (3.0 if h == 1 else 1.0)
                        for h in range(N_HOSTS)})
    # a second tenant surges while host 1 is flagged slow
    evict_verts = np.arange(3 * g.n // 4,
                            3 * g.n // 4 + int(shift_frac * g.n))
    pre_evict = mon.incumbent.copy()
    j_evict_before = None
    evict_reports = []
    for _ in range(3):
        evict_live = _shift(mon.baseline, evict_verts)
        mon.observe_graph(evict_live)
        r = mon.tick()
        evict_reports.append(r)
        if r.remapped:
            break
    evict_committed = any(r.remapped for r in evict_reports)
    j_evict_before = plan.objective(mon.baseline, pre_evict)
    j_evict_after = plan.objective(mon.baseline, mon.incumbent)
    # every execute after the plan's initial warm-up — the monitor's
    # warm remaps, the re-timed incrementals, and the same-bucket
    # scratch runs — must have reused the compiled executables
    warm_retraces = sum(e.trace_count() for e in engines) - warm_traces0

    if warm_retraces != 0:
        raise SystemExit(f"FAIL: warm incremental remaps retraced "
                         f"{warm_retraces} times (must be 0)")

    payload = {
        "mode": "smoke" if smoke else "full",
        "n": g.n,
        "n_pe": topo.n_pe,
        "candidate_pairs": int(len(mon.pairs)),
        "config": {
            "quiet_windows": QUIET_WINDOWS, "jitter": JITTER,
            "shift_factor": SHIFT_FACTOR, "shift_frac": shift_frac,
            "drift_high": cfg.drift_high, "drift_low": cfg.drift_low,
            "drift_patience": cfg.drift_patience,
            "replay_margin": cfg.replay_margin,
            "dirty_hops": cfg.dirty_hops,
        },
        "windows": [_row(r) for r in mon.history],
        "steady_state": {
            "windows": QUIET_WINDOWS,
            "remaps": quiet_remaps,
        },
        "traffic_shift": {
            "committed": committed,
            "commits": len(commits),
            "trigger_window": (commits[0].window if committed else None),
            "dirty_vertices": (commits[0].dirty if committed else 0),
            "active_pairs": (commits[0].active_pairs if committed else 0),
            "objective_incumbent": j_old,
            "objective_incremental": j_incr,
            "objective_scratch": j_scratch,
            "objective_recovery": recovery,
            "incremental_seconds": t_incr,
            "scratch_seconds": t_scratch,
            "time_ratio": time_ratio,
            "predicted_improvement": predicted,
            "actual_improvement": actual,
        },
        "host_eviction": {
            "forced_by": next((r.forced_by for r in evict_reports
                               if r.forced_by), None),
            "committed": evict_committed,
            "objective_before": j_evict_before,
            "objective_after": j_evict_after,
        },
        "headline": {
            "quiet_remaps": quiet_remaps,
            "quiet_zero_remaps": quiet_remaps == 0,
            "objective_recovery": recovery,
            "recovery_ge_80pct": recovery >= 0.80,
            "time_ratio_vs_scratch": time_ratio,
            "time_lt_half_scratch": time_ratio < 0.5,
            "warm_retraces": int(warm_retraces),
            "warm_zero_retraces": warm_retraces == 0,
        },
    }
    from ._common import write_bench
    payload = write_bench(payload, out)
    report("remap/steady/remaps", 0, f"windows={QUIET_WINDOWS};remaps=0")
    report("remap/incremental_us", t_incr * 1e6,
           f"commits={len(commits)};"
           f"dirty={commits[0].dirty if committed else 0};"
           f"active={commits[0].active_pairs if committed else 0};"
           f"retraces={warm_retraces}")
    report("remap/scratch_us", t_scratch * 1e6,
           f"ratio={time_ratio:.2f}")
    report("remap/recovery", 0,
           f"{recovery:.2f};predicted={predicted:.3f};"
           f"actual={actual:.3f}")
    report("remap/evict", 0,
           f"forced={payload['host_eviction']['forced_by']};"
           f"committed={evict_committed}")
    report("remap/json_written", 0, out)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="64-vertex workload (CI)")
    ap.add_argument("--out", default="BENCH_remap.json")
    args = ap.parse_args(argv)
    run(lambda n, us, d: print(f"{n},{us:.0f},{d}", flush=True),
        smoke=args.smoke, out=args.out)


if __name__ == "__main__":
    main()
