"""Benchmark harness — one module per paper table.  Prints
``name,us_per_call,derived`` CSV rows (harness contract)."""

from __future__ import annotations

import sys


def main() -> None:
    from . import (bench_construction, bench_kernels, bench_local_search,
                   bench_mesh_mapping)

    def report(name: str, us: float, derived: str = ""):
        print(f"{name},{us:.0f},{derived}", flush=True)

    print("name,us_per_call,derived")
    bench_construction.run(report)
    bench_local_search.run(report)
    bench_kernels.run(report)
    bench_mesh_mapping.run(report)


if __name__ == "__main__":
    main()
