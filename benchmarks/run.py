"""Benchmark harness — one module per paper table.  Prints
``name,us_per_call,derived`` CSV rows (harness contract)."""

from __future__ import annotations

import sys


def main() -> None:
    from . import (bench_construction, bench_engine, bench_kernels,
                   bench_local_search, bench_mesh_mapping,
                   bench_multilevel, bench_portfolio, bench_remap,
                   bench_serve, bench_topology)

    def report(name: str, us: float, derived: str = ""):
        print(f"{name},{us:.0f},{derived}", flush=True)

    smoke = "--smoke" in sys.argv[1:]
    # record tracer spans for the whole run: every BENCH_*.json gets a
    # span_breakdown block (per-stage timing split) via write_bench
    from repro.obs import get_tracer
    get_tracer().enable(capacity=65536)
    print("name,us_per_call,derived")
    bench_construction.run(report)
    bench_local_search.run(report)
    # kernel-layer axis: writes BENCH_kernels.json (forms x paths x dtypes)
    bench_kernels.run(report, smoke=smoke)
    bench_mesh_mapping.run(report)
    # machine-model axis: writes BENCH_topology.json next to the CSV stream
    bench_topology.run(report, smoke=smoke)
    # refinement-engine axis: writes BENCH_engine.json (host vs device)
    bench_engine.run(report, smoke=smoke)
    # multilevel axis: writes BENCH_multilevel.json (flat vs V-cycle)
    bench_multilevel.run(report, smoke=smoke)
    # portfolio axis: writes BENCH_portfolio.json (single vs multistart)
    bench_portfolio.run(report, smoke=smoke)
    # serving axis: writes BENCH_serve.json (MappingService vs per-request)
    bench_serve.run(report, smoke=smoke)
    # closed-loop axis: writes BENCH_remap.json (drift -> gate -> remap)
    bench_remap.run(report, smoke=smoke)


if __name__ == "__main__":
    main()
