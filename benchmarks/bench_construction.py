"""Paper table: initial-construction quality (guide §2.2 / [15]).

Columns: graph, construction, J(C,D,Π), seconds.  Reproduces the paper's
claim ordering: hierarchytopdown ≤ hierarchybottomup < growing < identity
< random on structured communication graphs.
"""

from __future__ import annotations

import time

from repro.core import Hierarchy, grid3d, qap_objective, random_geometric
from repro.core.construction import CONSTRUCTIONS, construct

BENCH_GRAPHS = {
    "grid3d_8x8x8": (lambda: grid3d(8, 8, 8),
                     Hierarchy((16, 8, 4), (1.0, 10.0, 100.0))),
    "torus_8x8x8": (lambda: grid3d(8, 8, 8, torus=True),
                    Hierarchy((16, 8, 4), (1.0, 10.0, 100.0))),
    "rgg_512": (lambda: random_geometric(512, 0.08, seed=7),
                Hierarchy((16, 8, 4), (1.0, 10.0, 100.0))),
}


def run(report):
    for gname, (make, h) in BENCH_GRAPHS.items():
        g = make()
        for name in sorted(CONSTRUCTIONS):
            t0 = time.perf_counter()
            perm = construct(name, g, h, seed=0, preconfiguration="eco")
            dt = time.perf_counter() - t0
            j = qap_objective(g, h, perm)
            report(f"construction/{gname}/{name}", dt * 1e6, f"J={j:.0f}")


if __name__ == "__main__":
    run(lambda n, us, d: print(f"{n},{us:.0f},{d}"))
