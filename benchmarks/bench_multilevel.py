"""Multilevel benchmark — flat device engine vs the coarsen → map →
uncoarsen V-cycle on the mesh-collective workload.

Same refinement problem per cell (random construction seed, same
candidate neighborhood, same device engine and sweep budget) run flat
(PR 3 single-level pipeline) and through the multilevel V-cycle
(:mod:`repro.multilevel`, eco knobs), at fleet sizes
n ∈ {256, 1024, 4096} across tree / torus / matrix machine models.
Writes ``BENCH_multilevel.json``: objective and wall-time per cell plus
the headline per-(n, topology) comparison — the acceptance bar is
multilevel objective ≤ flat at n ∈ {1024, 4096} on every topology, at
comparable wall-time (the coarse levels must pay for themselves).

Wall-times exclude compilation (one warm-up map per mapper) but include
the V-cycle's coarsening, per-level pair generation, and coarsest-level
construction: graph-side caches are cleared before the timed run so the
multilevel pipeline pays its full per-graph cost honestly.

    python -m benchmarks.bench_multilevel [--smoke] [--out ...]
"""

from __future__ import annotations

import argparse
import time

from repro.core import Mapper, MappingSpec, MultilevelSpec, tpu_v5e_fleet
from repro.topology import MatrixTopology, tpu_v5e_torus

from .bench_topology import mesh_workload

MAX_SWEEPS = 64
PAIR_DIST = 2


def _machines(pods: int) -> dict:
    torus = tpu_v5e_torus(pods=pods)
    return {
        "tree": tpu_v5e_fleet(pods=pods),
        "torus": torus,
        # explicit-matrix view of the torus: the general sparse-QAP path
        "matrix": MatrixTopology(matrix=torus.distance_matrix()),
    }


def _timed_map(mapper: Mapper, g, spec: MappingSpec):
    """One warmed, cache-honest map: compile on a warm-up run, then
    clear the plan's graph-side caches so the timed run pays pyramid
    build, pair generation, and construction for real."""
    mapper.map(g, spec=spec)                    # warm-up: compiles
    mapper.lower_for(g, spec).clear_request_caches()
    t0 = time.perf_counter()
    res = mapper.map(g, spec=spec)
    return res, time.perf_counter() - t0


def run(report, smoke: bool = False, out: str = "BENCH_multilevel.json"):
    pod_counts = [1] if smoke else [1, 4, 16]   # n = 256 · pods
    flat = MappingSpec(construction="random", neighborhood="communication",
                       neighborhood_dist=PAIR_DIST, preconfiguration="eco",
                       engine="device", seed=0, max_sweeps=MAX_SWEEPS)
    ml = flat.replace(multilevel=MultilevelSpec())      # eco: (4, 64)
    cells, headline = [], []
    for pods in pod_counts:
        g = mesh_workload(pods)
        for tname, machine in _machines(pods).items():
            mapper = Mapper(machine, flat)
            out_pair = {}
            for mode, spec in (("flat", flat), ("multilevel", ml)):
                res, dt = _timed_map(mapper, g, spec)
                out_pair[mode] = (res, dt)
                cells.append({
                    "n": g.n, "topology": tname, "pipeline": mode,
                    "seconds": dt,
                    "initial_objective": res.initial_objective,
                    "final_objective": res.final_objective,
                })
                report(f"multilevel/{tname}/n{g.n}/{mode}", dt * 1e6,
                       f"J={res.final_objective:.4e}")
            rf, tf = out_pair["flat"]
            rm, tm = out_pair["multilevel"]
            tol = 1e-5 * max(1.0, abs(rf.final_objective))
            cmp = {
                "n": g.n, "topology": tname,
                "flat_J": rf.final_objective,
                "multilevel_J": rm.final_objective,
                "improvement": 1.0 - rm.final_objective /
                    max(rf.final_objective, 1e-12),
                "flat_seconds": tf, "multilevel_seconds": tm,
                "ml_wall_over_flat": tm / max(tf, 1e-12),
                "objective_leq_flat":
                    rm.final_objective <= rf.final_objective + tol,
            }
            headline.append(cmp)
            report(f"multilevel/{tname}/n{g.n}/headline", 0,
                   f"improvement={cmp['improvement']:.1%};"
                   f"wall_x{cmp['ml_wall_over_flat']:.2f};"
                   f"leq={cmp['objective_leq_flat']}")

    payload = {"mode": "smoke" if smoke else "full",
               "workload": "mesh-collectives",
               "max_sweeps": MAX_SWEEPS, "pair_dist": PAIR_DIST,
               "multilevel": {"preconfiguration": "eco",
                              "levels": 4, "coarsen_min": 64},
               "cells": cells, "headline": headline}
    from ._common import write_bench
    payload = write_bench(payload, out)
    report("multilevel/json_written", 0, out)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="single-pod fleet only (CI)")
    ap.add_argument("--out", default="BENCH_multilevel.json")
    args = ap.parse_args(argv)
    run(lambda n, us, d: print(f"{n},{us:.0f},{d}", flush=True),
        smoke=args.smoke, out=args.out)


if __name__ == "__main__":
    main()
