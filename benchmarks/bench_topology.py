"""Topology benchmark — the machine-model axis of the perf trajectory.

Maps the mesh workload (the synthetic ring-collective traffic graph of
``bench_mesh_mapping``) under every registered machine model and writes
``BENCH_topology.json``: objective + wall-time per
topology × construction × neighborhood, plus the headline tree-vs-torus
comparison — the mapping built against the honest v5e ICI torus model vs
the mapping built against the tree approximation, both *scored on the
torus* (the machine the traffic actually crosses).

    python -m benchmarks.bench_topology [--smoke] [--out BENCH_topology.json]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import Mapper, MappingSpec, from_edges, qap_objective, \
    tpu_v5e_fleet
from repro.topology import (DragonflyTopology, FatTreeTopology,
                            MatrixTopology, tpu_v5e_torus)


def mesh_workload(pods: int = 2, w_model: float = 1e6, w_data: float = 1e6,
                  w_pod: float = 1e6):
    """Production-mesh collective traffic: the (pod, data=16, model=16)
    mesh's ring all-reduces along *both* mesh axes (plus the pod axis) —
    genuinely 2D nearest-neighbor traffic, which a tree hierarchy cannot
    represent but a torus can.  Logical id = m + 16·(d + 16·p)."""
    data, model = 16, 16
    n = pods * data * model
    us, vs, ws = [], [], []

    def nid(p, d, m):
        return m + model * (d + data * p)

    for p in range(pods):
        for d in range(data):
            for m in range(model):
                us.append(nid(p, d, m))
                vs.append(nid(p, d, (m + 1) % model))
                ws.append(w_model)
                us.append(nid(p, d, m))
                vs.append(nid(p, (d + 1) % data, m))
                ws.append(w_data)
                if p + 1 < pods:
                    us.append(nid(p, d, m))
                    vs.append(nid(p + 1, d, m))
                    ws.append(w_pod)
    return from_edges(n, np.array(us), np.array(vs), np.array(ws))


def fleet_topologies(pods: int) -> dict:
    """One instance of every registered backend at fleet size 256·pods."""
    torus = tpu_v5e_torus(pods=pods)
    n = torus.n_pe
    return {
        "tree": tpu_v5e_fleet(pods=pods),
        "torus": torus,
        "fattree": FatTreeTopology(
            arities=(16, 4, 4) if pods == 1 else (16, 4, 4, pods),
            link_costs=(1.0, 2.0, 6.0) if pods == 1
            else (1.0, 2.0, 6.0, 30.0)),
        "dragonfly": DragonflyTopology(pes_per_router=4,
                                       routers_per_group=8,
                                       n_groups=n // 32),
        # explicit-matrix view of the torus: exercises the general
        # sparse-QAP path at fleet scale
        "matrix": MatrixTopology(matrix=torus.distance_matrix()),
    }


def run(report, smoke: bool = False, out: str = "BENCH_topology.json"):
    pods = 1 if smoke else 2
    g = mesh_workload(pods)
    topos = fleet_topologies(pods)
    constructions = ["hierarchytopdown"] if smoke else \
        ["hierarchytopdown", "growing"]
    neighborhoods = [None, "communication"]
    base = MappingSpec(preconfiguration="fast" if smoke else "eco",
                       neighborhood_dist=3, seed=0,
                       max_sweeps=4 if smoke else 8)

    cells = []
    perms: dict[tuple, np.ndarray] = {}
    for tname, topo in topos.items():
        mapper = Mapper(topo, base)
        for cons in constructions:
            for nb in neighborhoods:
                spec = base.replace(construction=cons, neighborhood=nb)
                t0 = time.perf_counter()
                res = mapper.map(g, spec=spec)
                dt = time.perf_counter() - t0
                cell = {
                    "topology": tname,
                    "construction": cons,
                    "neighborhood": nb or "none",
                    "objective": res.final_objective,
                    "initial_objective": res.initial_objective,
                    "seconds": dt,
                }
                cells.append(cell)
                perms[(tname, cons, nb or "none")] = res.perm
                report(f"topology/{tname}/{cons}/{nb or 'none'}",
                       dt * 1e6, f"J={res.final_objective:.3e}")

    # headline: tree-approximated vs torus-native, both scored on the torus
    torus = topos["torus"]
    key = ("hierarchytopdown", "communication")
    perm_tree = perms[("tree",) + key]
    perm_torus = perms[("torus",) + key]
    cmp = {
        "workload": f"mesh-collectives-n{g.n}",
        "scored_on": "torus",
        "tree_approx_J": qap_objective(g, torus, perm_tree),
        "torus_native_J": qap_objective(g, torus, perm_torus),
    }
    cmp["torus_native_wins"] = cmp["torus_native_J"] < cmp["tree_approx_J"]
    cmp["improvement"] = 1.0 - cmp["torus_native_J"] / \
        max(cmp["tree_approx_J"], 1e-12)
    report("topology/tree_vs_torus", 0,
           f"tree_J={cmp['tree_approx_J']:.3e};"
           f"torus_J={cmp['torus_native_J']:.3e};"
           f"improvement={cmp['improvement']:.1%}")

    payload = {"mode": "smoke" if smoke else "full",
               "workload": cmp["workload"],
               "cells": cells,
               "tree_vs_torus": cmp}
    from ._common import write_bench
    payload = write_bench(payload, out)
    report("topology/json_written", 0, out)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="single-pod fleet, fast preconfiguration (CI)")
    ap.add_argument("--out", default="BENCH_topology.json")
    args = ap.parse_args(argv)
    run(lambda n, us, d: print(f"{n},{us:.0f},{d}", flush=True),
        smoke=args.smoke, out=args.out)


if __name__ == "__main__":
    main()
