"""Serving benchmark — the shape-bucketed `MappingService` vs per-request
``Mapper.map`` on mixed-shape traffic.

The traffic model is a serving fleet's steady state: a handful of
distinct communication patterns (graphs of different densities, so they
land in different shape buckets) recur across requests — recompiled
serving programs usually re-emit the pattern they had before.  Both
sides get the same shuffled request stream and a compile warm-up on
*separate* graphs (the warm result cache starts cold, so every hit it
scores during the timed run is earned from the traffic's own repeats):

  * baseline — one ``Mapper`` session, sequential ``map()`` per request
    (plans are cached, so the baseline already amortizes lowering);
  * service — ``MappingService`` with the fleet's
    ``placement_service_config()``: pow2 buckets, dynamic batching into
    vmapped ``execute_batch`` calls, in-tick dedup, warm result cache.

Writes ``BENCH_serve.json``: wall-clock throughput, per-request p50/p99
latency, batch/cache accounting, and the headline
``throughput_speedup`` (acceptance bar: >= 3x on this traffic).

    python -m benchmarks.bench_serve [--smoke] [--out BENCH_serve.json]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import Hierarchy, Mapper, MappingSpec, random_geometric, \
    tpu_v5e_fleet
from repro.launch.serve import MappingService
from repro.launch.specs import placement_service_config

REPEATS = 8          # requests per distinct traffic pattern
STRUCTURES = 6       # distinct patterns (≈3 pow2 buckets at these radii)


def _machine(smoke: bool):
    return (Hierarchy((4, 4, 4), (1.0, 10.0, 100.0)) if smoke
            else tpu_v5e_fleet(pods=1))


def _spec() -> MappingSpec:
    return MappingSpec(construction="random", neighborhood="communication",
                       neighborhood_dist=2, preconfiguration="fast",
                       engine="device", seed=0)


def _traffic(n: int, rng: np.random.Generator):
    """Mixed-shape request stream: STRUCTURES distinct densities (so the
    service sees several shape buckets), REPEATS requests each,
    shuffled."""
    base = 0.8 / np.sqrt(n)
    distinct = [random_geometric(n, base * (1.0 + 0.35 * i), seed=100 + i)
                for i in range(STRUCTURES)]
    stream = [g for g in distinct for _ in range(REPEATS)]
    rng.shuffle(stream)
    return distinct, stream


def _pct(lat, q):
    lat = sorted(lat)
    return lat[min(len(lat) - 1, int(q * len(lat)))] if lat else 0.0


def run(report, smoke: bool = False, out: str = "BENCH_serve.json"):
    machine = _machine(smoke)
    spec = _spec()
    rng = np.random.default_rng(0)
    distinct, stream = _traffic(machine.n_pe, rng)
    # compile warm-up on weight-perturbed copies: same buckets, shapes,
    # and executables, different content — the warm result cache starts
    # cold for the timed stream, so every hit it scores is earned
    def _perturb(scale):
        from repro.core import CommGraph
        return [CommGraph(g.xadj.copy(), g.adjncy.copy(),
                          g.adjwgt * scale, g.vwgt.copy())
                for g in distinct]

    warm_single = _perturb(1.5)
    warm_burst = _perturb(2.0)

    # ---- baseline: sequential per-request Mapper.map
    base_mapper = Mapper(machine, spec)
    for g in warm_single:
        base_mapper.map(g)
    lat_base = []
    t0 = time.perf_counter()
    for g in stream:
        t1 = time.perf_counter()
        base_mapper.map(g)
        lat_base.append(time.perf_counter() - t1)
    t_base = time.perf_counter() - t0

    # ---- service: shape-bucketed dynamic batching + warm cache
    cfg = placement_service_config()
    svc = MappingService(Mapper(machine, spec), **cfg)
    try:
        # warm both executables per bucket: singles first, then one
        # burst of fresh content so each bucket's padded-batch
        # executable compiles too (a repeat burst would just hit the
        # result cache and leave the batch path cold)
        for g in warm_single:
            svc.map(g, timeout=600)
        burst = [svc.submit(g) for g in warm_burst]
        for _ in burst:
            svc.results.get(timeout=600)
        svc.reset_stats()
        t0 = time.perf_counter()
        tickets = [svc.submit(g) for g in stream]
        done = 0
        while done < len(tickets):
            _, res = svc.results.get(timeout=600)
            if isinstance(res, Exception):
                raise res
            done += 1
        t_serve = time.perf_counter() - t0
        stats = svc.stats()
        info = svc.mapper.cache_info()
    finally:
        svc.close()

    n_req = len(stream)
    thr_base = n_req / max(t_base, 1e-9)
    thr_serve = n_req / max(t_serve, 1e-9)
    speedup = thr_serve / max(thr_base, 1e-9)
    payload = {
        "mode": "smoke" if smoke else "full",
        "n_pe": machine.n_pe,
        "requests": n_req,
        "distinct_structures": STRUCTURES,
        "repeats_per_structure": REPEATS,
        "service_config": cfg,
        "baseline": {
            "seconds": t_base,
            "throughput_rps": thr_base,
            "latency_p50_s": _pct(lat_base, 0.50),
            "latency_p99_s": _pct(lat_base, 0.99),
        },
        "service": {
            "seconds": t_serve,
            "throughput_rps": thr_serve,
            "latency_p50_s": stats["latency_p50_s"],
            "latency_p99_s": stats["latency_p99_s"],
            "batches": stats["batches"],
            "batched_requests": stats["batched_requests"],
            "max_batch_seen": stats["max_batch_seen"],
            "result_cache_hits": stats["result_cache_hits"],
            "in_tick_deduped": stats["in_tick_deduped"],
            "peak_queue_depth": stats["peak_queue_depth"],
            "plan_builds": info["plan_builds"],
            "plan_buckets": sorted(info["plans"]),
        },
        "headline": {
            "throughput_speedup": speedup,
            "meets_3x": speedup >= 3.0,
        },
    }
    from ._common import write_bench
    payload = write_bench(payload, out)
    report("serve/baseline/us_per_req", t_base / n_req * 1e6,
           f"p99={_pct(lat_base, 0.99):.3f}s")
    report("serve/service/us_per_req", t_serve / n_req * 1e6,
           f"p99={stats['latency_p99_s']:.3f}s;"
           f"batches={stats['batches']};"
           f"warm_hits={stats['result_cache_hits']}")
    report("serve/speedup", 0,
           f"x{speedup:.2f};meets_3x={speedup >= 3.0}")
    report("serve/json_written", 0, out)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="64-PE machine (CI)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)
    run(lambda n, us, d: print(f"{n},{us:.0f},{d}", flush=True),
        smoke=args.smoke, out=args.out)


if __name__ == "__main__":
    main()
