"""Framework-integration benchmark: compiled SPMD step → traffic graph →
VieM placement vs identity/random — the QAP objective is modeled
communication cost on the v5e fleet hierarchy."""

from __future__ import annotations

import time

import numpy as np

from repro.core import Mapper, qap_objective, tpu_v5e_fleet
from repro.core.comm_model import device_comm_graph, logical_traffic_summary
from repro.launch.specs import placement_spec


def _compiled_hlo():
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    if len(jax.devices()) < 512:
        return None
    mesh = jax.make_mesh((2, 16, 16), ("pod", "data", "model"))
    d = 512

    def step(w, x):
        def body(c, wl):
            return jnp.tanh(c @ wl), ()
        h, _ = jax.lax.scan(body, x, w)
        return jnp.sum(h * h)

    ws = NamedSharding(mesh, P(None, "data", "model"))
    xs = NamedSharding(mesh, P(("pod", "data"), "model"))
    return jax.jit(step, in_shardings=(ws, xs),
                   out_shardings=NamedSharding(mesh, P())).lower(
        jax.ShapeDtypeStruct((8, d, d), jnp.bfloat16),
        jax.ShapeDtypeStruct((64, d), jnp.bfloat16)).compile().as_text()


def run(report):
    hlo = _compiled_hlo()
    if hlo is None:
        # single-device pytest run: use a canned ring-pattern graph
        from repro.core import from_edges
        n = 512
        us, vs, ws = [], [], []
        for r in range(32):
            members = [r + 32 * i for i in range(16)]
            for i in range(16):
                us.append(members[i])
                vs.append(members[(i + 1) % 16])
                ws.append(1e6)
        g = from_edges(n, np.array(us), np.array(vs), np.array(ws))
        src = "synthetic-rings"
    else:
        g = device_comm_graph(hlo, 512)
        src = "compiled-hlo"

    h = tpu_v5e_fleet(pods=2)
    j_ident = qap_objective(g, h, np.arange(512))
    j_rand = qap_objective(g, h,
                           np.random.default_rng(0).permutation(512))
    t0 = time.perf_counter()
    res = Mapper(h, placement_spec(seed=0)).map(g)
    dt = time.perf_counter() - t0
    report(f"mesh_mapping/{src}/identity", 0, f"J={j_ident:.3e}")
    report(f"mesh_mapping/{src}/random", 0, f"J={j_rand:.3e}")
    report(f"mesh_mapping/{src}/viem", dt * 1e6,
           f"J={res.final_objective:.3e};"
           f"vs_identity={res.final_objective/max(j_ident,1e-9):.3f}")
    tr = logical_traffic_summary(g, h, res.perm)
    report(f"mesh_mapping/{src}/viem_traffic", 0,
           ";".join(f"{k}={v:.2e}" for k, v in tr.items()))


if __name__ == "__main__":
    run(lambda n, us, d: print(f"{n},{us:.0f},{d}"))
