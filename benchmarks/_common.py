"""Shared benchmark plumbing: provenance metadata + tracer breakdowns.

Every ``BENCH_*.json`` goes through :func:`write_bench`, which stamps
the payload with a ``meta`` block (schema version, jax backend and
version, git SHA, timestamp) so archived results are comparable across
machines and commits, and — when the global tracer is enabled (the
``benchmarks.run`` harness turns it on) — a ``span_breakdown`` block
with per-span-name wall-time aggregates (the per-kernel-form timing
split: plan.lower vs plan.construct vs plan.refine vs vcycle.refine
etc.).
"""

from __future__ import annotations

import json
import subprocess
import time

BENCH_SCHEMA_VERSION = 2


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True,
            text=True, timeout=5, check=True).stdout.strip()
    except Exception:
        return "unknown"


def bench_metadata() -> dict:
    import jax
    return {
        "schema_version": BENCH_SCHEMA_VERSION,
        "backend": jax.default_backend(),
        # the repo-wide Pallas convention: interpret off-TPU
        "pallas_interpret": jax.default_backend() != "tpu",
        "jax_version": jax.__version__,
        "git_sha": _git_sha(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
    }


def write_bench(payload: dict, out: str) -> dict:
    """Stamp ``payload`` with provenance metadata (and the tracer's span
    breakdown when spans were recorded), then write it to ``out``."""
    from repro.obs import get_tracer, span_breakdown
    payload = dict(payload)
    payload["meta"] = bench_metadata()
    tracer = get_tracer()
    if len(tracer):
        payload["span_breakdown"] = span_breakdown(tracer.spans())
    with open(out, "w") as fh:
        json.dump(payload, fh, indent=2)
    return payload
