"""Paper table: local-search neighborhoods (guide §2.1 / [15]).

Time vs quality for N², N² pruned, N_C, N_C^d (d = 2, 10) from a random
construction — the paper's claim: communication-graph neighborhoods reach
N²-class quality at a fraction of the evaluations.  Also benchmarks the
sparse O(deg) gain vs the dense O(n) update it replaces.
"""

from __future__ import annotations

import time

from repro.core import Hierarchy, grid3d, qap_objective
from repro.core.construction import construct
from repro.core.local_search import local_search, parallel_sweep_search, \
    communication_pairs
from repro.core.objective import swap_gain

H = Hierarchy((16, 8, 4), (1.0, 10.0, 100.0))

VARIANTS = [
    ("nsquare", {}),
    ("nsquarepruned", {}),
    ("communication_d1", {"neighborhood": "communication",
                          "communication_neighborhood_dist": 1}),
    ("communication_d2", {"neighborhood": "communication",
                          "communication_neighborhood_dist": 2}),
    ("communication_d10", {"neighborhood": "communication",
                           "communication_neighborhood_dist": 10}),
]


def run(report):
    g = grid3d(8, 8, 8)
    j0 = qap_objective(g, H, construct("random", g, H, seed=0))
    for name, kw in VARIANTS:
        perm = construct("random", g, H, seed=0)
        nbhd = kw.get("neighborhood", name)
        t0 = time.perf_counter()
        stats = local_search(
            g, H, perm, neighborhood=nbhd,
            communication_neighborhood_dist=kw.get(
                "communication_neighborhood_dist", 10), seed=0)
        dt = time.perf_counter() - t0
        report(f"local_search/grid512/{name}", dt * 1e6,
               f"J={stats.final_objective:.0f};evals={stats.evaluated};"
               f"J0={j0:.0f}")

    # TPU-adapted batched sweep
    perm = construct("random", g, H, seed=0)
    t0 = time.perf_counter()
    stats = parallel_sweep_search(g, H, perm, communication_pairs(g, 2))
    dt = time.perf_counter() - t0
    report("local_search/grid512/parallel_sweep_d2", dt * 1e6,
           f"J={stats.final_objective:.0f};evals={stats.evaluated}")

    # sparse vs dense gain evaluation cost (the guide's O(deg) vs O(n))
    perm = construct("random", g, H, seed=0)
    pairs = communication_pairs(g, 1)[:512]
    t0 = time.perf_counter()
    for u, v in pairs:
        swap_gain(g, H, perm, int(u), int(v))
    t_sparse = time.perf_counter() - t0
    C, D = g.to_dense(), H.distance_matrix()
    t0 = time.perf_counter()
    for u, v in pairs:
        # dense O(n) update á la Brandfass: two full row recomputations
        du = (C[u] * D[perm[u]][perm]).sum() - (C[u] * D[perm[v]][perm]).sum()
        dv = (C[v] * D[perm[v]][perm]).sum() - (C[v] * D[perm[u]][perm]).sum()
        _ = du + dv
    t_dense = time.perf_counter() - t0
    report("gain_eval/sparse_per_512", t_sparse * 1e6, "O(deg) oracle")
    report("gain_eval/dense_per_512", t_dense * 1e6, "O(n) rows")


if __name__ == "__main__":
    run(lambda n, us, d: print(f"{n},{us:.0f},{d}"))
